#!/usr/bin/env bash
# One-command verify entrypoint.
#
#   scripts/ci.sh         tier-1: the full suite, fail-fast (the command
#                         ROADMAP.md pins as the repo's verify gate)
#   scripts/ci.sh fast    quick iteration tier: everything but the slow
#                         paper-table / order-2 compiles (-m "not slow")
#   scripts/ci.sh bench-smoke
#                         kernel-layer benchmark in tiny dry-run shape:
#                         fused + unfused + Pallas paths must run and stay
#                         bit-exact, so kernel regressions fail CI rather
#                         than only the offline benchmark
#   scripts/ci.sh sweep-smoke
#                         design-space sweep in the 7-bit CI shape, BOTH
#                         modes at 1 and 2 workers: sharded (shard ->
#                         merge) and live (work-stealing over one shared
#                         store dir) must each end bit-identical to a
#                         serial compile with every key compiled exactly
#                         once, and live must match or beat the skewed
#                         sharded baseline's jobs/sec
#   scripts/ci.sh search-smoke
#                         search-backend tier: the backend bit-identity /
#                         speculative-TBW tests plus the throughput
#                         benchmark in smoke shape — the jitted jax
#                         backend must run bit-identical to the numpy
#                         golden backend and match or beat its evals/sec
#                         on the order-2 extended FQA grid (the benchmark
#                         prints a skip notice where jax x64 is
#                         unavailable)
#   scripts/ci.sh serve-smoke
#                         serving tier: the serve test file (coalesced
#                         admission bit-identity vs the serial path,
#                         tenant pin/evict vs store LRU, retrace bound)
#                         plus the load benchmark in smoke shape — the
#                         coalesced engine must beat serial tokens/sec
#                         at >= 4 concurrent clients and a warm tenant's
#                         first token must land before a cold one's
#   scripts/ci.sh tune-smoke
#                         batched-Remez + autotuner tier: the remez parity
#                         tests (batched exchange bit-identical to the
#                         serial loop across the NAF zoo), then a tiny
#                         autotune sweep against a throwaway store — the
#                         persisted per-device config must round-trip, be
#                         picked up by compile_or_load, and leave the
#                         compiled artifact byte-identical to an untuned
#                         compile
#   scripts/ci.sh analyze
#                         static-analysis tier: the JAX hot-path lint over
#                         the golden/serving/compiler files must come back
#                         clean (every deliberate exception carries an
#                         inline "analysis: allow(<rule>)" justification),
#                         then the smoke grid is compiled and every config
#                         gets an exact per-segment bit-width certificate —
#                         overflow-freedom proven, or CI fails with the
#                         concrete violating interval
#   scripts/ci.sh seg-smoke
#                         segmentation tier: the property suite for the
#                         whole segmentation stack (breakpoint
#                         monotonicity, exact domain tiling, per-segment
#                         MAE_t feasibility, cross-segmenter agreement
#                         with non-monotone witnesses, memoized == plain
#                         for the non-uniform search), then a fresh
#                         uniform-vs-non-uniform compile pair whose
#                         non-uniform table must not grow the segment
#                         count, must hold MAE_t and must certify
#                         overflow-free.  The property suite also runs
#                         inside tier-1 (it is part of the default
#                         pytest gate); this mode is the quick,
#                         segmentation-only slice of it
#   scripts/ci.sh chaos-smoke
#                         fault-injection tier: the failpoint/robustness
#                         test file, then scripts/chaos.py --smoke — the
#                         live sweep with three workers crash-injected at
#                         distinct pipeline points (mid-compile, post-
#                         claim, publish-before-release) must complete
#                         the grid bit-identical to a serial compile
#                         with an exactly-once compile ledger; a merge
#                         killed mid-import must finish on clean retry;
#                         and a tenant warm-up failure plus a deadline
#                         expiry must leave a healthy tenant's tokens
#                         bit-identical to a fault-free run
#   scripts/ci.sh docs-check
#                         every python snippet in docs/*.md parses and
#                         its imports resolve; intra-repo doc links are
#                         unbroken
#
# Extra args after the mode are forwarded to pytest, e.g.
#   scripts/ci.sh fast -k compiler
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

mode="${1:-tier1}"
[ "$#" -gt 0 ] && shift

case "$mode" in
  tier1)
    exec python -m pytest -x -q "$@"
    ;;
  fast)
    exec python -m pytest -q -m "not slow" "$@"
    ;;
  sweep-smoke)
    exec python -m benchmarks.sweep_scaling --smoke --mode both \
         --hosts 1 2 "$@"
    ;;
  search-smoke)
    python -m pytest -q tests/test_searchspace.py "$@" || exit 1
    exec python -m benchmarks.search_throughput --smoke \
         --out BENCH_search.json
    ;;
  serve-smoke)
    python -m pytest -q tests/test_serve.py "$@" || exit 1
    exec python -m benchmarks.serve_load --smoke --out BENCH_serve.json
    ;;
  tune-smoke)
    python -m pytest -q tests/test_remez.py "$@" || exit 1
    tunedir="$(mktemp -d)"
    trap 'rm -rf "$tunedir"' EXIT
    exec python -m repro.tune.autotune --store "$tunedir" --smoke --verify
    ;;
  analyze)
    python -m repro.analysis --lint "$@" || exit 1
    exec python -m repro.analysis --certify-grid --smoke
    ;;
  seg-smoke)
    python -m pytest -q tests/test_core_segmentation.py "$@" || exit 1
    exec python - <<'PY'
import dataclasses
from repro.analysis import certify_table
from repro.core import FWLConfig, PPAScheme, compile_ppa_table

cfg = FWLConfig(7, 7, (7,), (7,), 7)
uni = PPAScheme(1, None, "fqa_fast")
non = dataclasses.replace(uni, segmenter="nonuniform")
t_u = compile_ppa_table("sigmoid", cfg, uni)
t_n = compile_ppa_table("sigmoid", cfg, non)
assert t_n.num_segments <= t_u.num_segments, \
    f"non-uniform grew the table: {t_u.num_segments} -> {t_n.num_segments}"
assert t_n.mae_hard <= t_n.mae_t + 1e-12, "non-uniform table misses MAE_t"
cert = certify_table(t_n)
assert cert.ok, f"non-uniform table failed certification: {cert.violations}"
print(f"seg-smoke: ok (uniform {t_u.num_segments} -> "
      f"non-uniform {t_n.num_segments} segments, "
      f"mae {t_n.mae_hard:.3e} <= {t_n.mae_t:.3e}, certified <= "
      f"{cert.max_bits} bits)")
PY
    ;;
  chaos-smoke)
    python -m pytest -q tests/test_faults.py "$@" || exit 1
    exec python scripts/chaos.py --smoke
    ;;
  docs-check)
    exec python scripts/docs_check.py "$@"
    ;;
  bench-smoke)
    out="$(python -m benchmarks.kernel_throughput --smoke)" || exit 1
    echo "$out"
    case "$out" in
      *False*) echo "bench-smoke: bit-exactness check FAILED" >&2; exit 1 ;;
    esac
    ;;
  *)
    echo "usage: scripts/ci.sh" \
         "[tier1|fast|bench-smoke|sweep-smoke|search-smoke|serve-smoke|tune-smoke|analyze|seg-smoke|docs-check]" \
         "[extra args...]" >&2
    exit 2
    ;;
esac
