#!/usr/bin/env python
"""Documentation checker: imports in doc snippets + intra-repo links.

Docs rot silently — an entrypoint gets renamed and the handbook keeps
recommending it.  This checker keeps `docs/*.md` (and the top-level
`*.md` anchors) honest without executing anything expensive:

  * every fenced ``python`` code block must parse, and every import it
    names must resolve: ``import a.b`` imports, ``from m import x`` has
    an ``x`` attribute (or ``m.x`` is a submodule).  Snippet *bodies* are
    not executed — this is an API-existence check, not a test run.
  * every relative markdown link ``[...](path)`` must point at a real
    file or directory in the repo (fragments are stripped; absolute
    ``http(s)://`` / ``mailto:`` links are out of scope).

Exit 0 when clean, 1 with a per-finding report otherwise.  Wired in as
``scripts/ci.sh docs-check``.
"""

from __future__ import annotations

import ast
import importlib
import re
import sys
from pathlib import Path
from typing import Iterator, List, Tuple

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

_FENCE = re.compile(r"^(`{3,})(\S*)\s*$")
# [text](target) — excluding images' extra bang is fine (same rules apply)
_LINK = re.compile(r"\[[^\]^\[]*\]\(([^)\s]+)\)")
_PY_LANGS = {"python", "py", "python3"}


def doc_files() -> List[Path]:
    docs = sorted((ROOT / "docs").glob("*.md"))
    anchors = [p for p in (ROOT / "README.md", ROOT / "ROADMAP.md")
               if p.exists()]
    return anchors + docs


def code_blocks(text: str) -> Iterator[Tuple[str, str, int]]:
    """Yield (lang, source, first_line_no) for each fenced block."""
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        m = _FENCE.match(lines[i])
        if not m:
            i += 1
            continue
        fence, lang = m.group(1), m.group(2).lower()
        body: List[str] = []
        start = i + 2                   # 1-based line of the body
        i += 1
        while i < len(lines) and not lines[i].startswith(fence):
            body.append(lines[i])
            i += 1
        i += 1                          # closing fence
        yield lang, "\n".join(body), start


def _import_ok(module: str) -> Tuple[bool, str]:
    try:
        importlib.import_module(module)
        return True, ""
    except Exception as e:              # ImportError and import-time errors
        return False, f"{type(e).__name__}: {e}"


def check_snippet(src: str, where: str, errors: List[str]) -> int:
    """Parse one python snippet and resolve its imports; count checked."""
    try:
        tree = ast.parse(src)
    except SyntaxError as e:
        errors.append(f"{where}: snippet does not parse: {e}")
        return 0
    checked = 0
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                checked += 1
                ok, err = _import_ok(alias.name)
                if not ok:
                    errors.append(f"{where}: import {alias.name}: {err}")
        elif isinstance(node, ast.ImportFrom):
            if node.level or node.module is None:
                continue                # relative import: not doc material
            ok, err = _import_ok(node.module)
            if not ok:
                errors.append(f"{where}: from {node.module} import ...: "
                              f"{err}")
                continue
            mod = importlib.import_module(node.module)
            for alias in node.names:
                if alias.name == "*":
                    continue
                checked += 1
                if hasattr(mod, alias.name):
                    continue
                ok, _ = _import_ok(f"{node.module}.{alias.name}")
                if not ok:
                    errors.append(
                        f"{where}: from {node.module} import {alias.name}: "
                        f"no such attribute or submodule")
    return checked


def check_links(text: str, doc: Path, errors: List[str]) -> int:
    checked = 0
    for m in _LINK.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        checked += 1
        resolved = (doc.parent / path).resolve()
        if not resolved.exists() and not (ROOT / path).exists():
            errors.append(f"{doc.relative_to(ROOT)}: broken link "
                          f"({target})")
    return checked


def main(argv=None) -> int:
    errors: List[str] = []
    snippets = imports = links = 0
    for doc in doc_files():
        text = doc.read_text()
        rel = doc.relative_to(ROOT)
        for lang, src, line in code_blocks(text):
            if lang not in _PY_LANGS:
                continue
            snippets += 1
            imports += check_snippet(src, f"{rel}:{line}", errors)
        links += check_links(text, doc, errors)
    for e in errors:
        print(f"[docs-check] {e}", file=sys.stderr)
    print(f"[docs-check] {len(doc_files())} docs: {snippets} python "
          f"snippets, {imports} imports resolved, {links} intra-repo "
          f"links checked, {len(errors)} problem(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
