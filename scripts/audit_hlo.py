"""Print the top memory / collective contributors for one dry-run cell.

  PYTHONPATH=src python scripts/audit_hlo.py <arch> <shape> [variant] [--multi-pod]
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import re
import sys

from repro.launch.dryrun import lower_cell
from repro.roofline.hlo_costs import (_COMP_HDR, _KNOWN_TRIPS, _NAME_REF,
                                      _NO_MATERIALIZE, _callees,
                                      _shape_bytes, _split_computations)

CONTROL = {"while", "call", "conditional", "custom-call"}


def main():
    arch, shape = sys.argv[1], sys.argv[2]
    variant = sys.argv[3] if len(sys.argv) > 3 and not sys.argv[3].startswith("--") else "baseline"
    multi = "--multi-pod" in sys.argv
    compiled, meta = lower_cell(arch, shape, multi, variant)
    txt = compiled.as_text()
    comps = _split_computations(txt)
    symbols = {c: {o.name: o.shape for o in ops} for c, ops in comps.items()}

    entry = next(l for l in txt.splitlines() if l.startswith("ENTRY"))
    ename = _COMP_HDR.match(entry.strip()).group(1)
    mult = {ename: 1.0}
    stack = [ename]
    fus = set()
    while stack:
        c = stack.pop()
        base = mult[c]
        for op in comps.get(c, []):
            cs = _callees(op)
            if op.kind == "while":
                mk = _KNOWN_TRIPS.search(op.attrs)
                trips = int(mk.group(1)) if mk else 1
                for r, n in cs:
                    if r in ("body", "condition") and \
                            mult.get(n, 0) < base * trips:
                        mult[n] = base * trips
                        stack.append(n)
            else:
                for r, n in cs:
                    if op.kind == "fusion":
                        fus.add(n)
                    if mult.get(n, 0) < base:
                        mult[n] = base
                        stack.append(n)

    mem_rows, coll_rows = [], []
    for c, ops in comps.items():
        m = mult.get(c)
        if m is None or c in fus:
            continue
        for op in ops:
            meta_m = re.search(r'op_name="([^"]*)"', op.args + op.attrs)
            tag = meta_m.group(1)[-70:] if meta_m else ""
            base_kind = re.sub(r"-(start|done)$", "", op.kind)
            if base_kind in ("all-gather", "all-reduce", "reduce-scatter",
                             "all-to-all", "collective-permute") \
                    and not op.kind.endswith("-done"):
                coll_rows.append((m * _shape_bytes(op.shape), m, base_kind,
                                  tag))
            if op.kind in _NO_MATERIALIZE or op.kind in CONTROL \
                    or op.kind.endswith("-done"):
                continue
            b = _shape_bytes(op.shape) + sum(
                _shape_bytes(symbols[c].get(n, ""))
                for n in _NAME_REF.findall(op.args))
            mem_rows.append((m * b, m, op.kind, tag))

    print(f"\n=== {arch} x {shape} x {variant} "
          f"({'multipod' if multi else 'pod'}) ===")
    print("--- top memory ops ---")
    mem_rows.sort(reverse=True)
    for b, m, k, tag in mem_rows[:14]:
        print(f"{b / 2**30:9.2f}GiB x{int(m):4d} {k:22s} {tag}")
    print("--- top collectives ---")
    coll_rows.sort(reverse=True)
    for b, m, k, tag in coll_rows[:10]:
        print(f"{b / 2**30:9.3f}GiB x{int(m):4d} {k:18s} {tag}")


if __name__ == "__main__":
    main()
