"""Back-compat shim: the HLO audit now lives in ``repro.analysis.hlo``.

  PYTHONPATH=src python scripts/audit_hlo.py <arch> <shape> [variant] [--multi-pod]

is equivalent to

  PYTHONPATH=src python -m repro.analysis --hlo <arch> <shape> [variant] [--multi-pod]
"""

import sys

from repro.analysis.hlo import main

if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
