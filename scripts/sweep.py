#!/usr/bin/env python
"""Multi-host design-space sweep CLI over the TableStore rendezvous.

Enumerates the paper's Tables I-VII x NAF-zoo grid as ``CompileJob``s and
runs it in one of two modes (``--mode``, see docs/OPERATIONS.md):

**sharded** (default) — runs *this host's* key-hash shard.  N hosts each
running

    python scripts/sweep.py --hosts N --host-id i --store /shard/i

cover the grid exactly once with no coordinator, each against its own
store directory; ``--merge-from`` reconciles the shard manifests
afterwards:

    python scripts/sweep.py --store /merged --merge-from /shard/0 /shard/1

**live** — no partition: N workers point at ONE shared store directory
(a shared filesystem) and steal work key by key via claim leases, so a
slow host's keys are absorbed by fast hosts and a dead host's stale
claims are taken over (``--claim-ttl``, required for takeover).  No
merge step:

    python scripts/sweep.py --mode live --claim-ttl 300 --store /nfs/grid
    # ... same command on every host

Both modes are resumable (store lookup before compile; re-run after a
kill and only missing keys compile) and exit 3 when keys were deferred
under another host's live claim.

``--backend numpy|jax`` / ``--speculate DEPTH`` pick how THIS host
executes the candidate scan (jitted x64 scan, TBW speculative probe
batching).  Execution-only: store keys and artifacts are bit-identical
across backends, so heterogeneous fleets share one store
(docs/OPERATIONS.md "Choosing the search backend per host").

Examples:
    scripts/sweep.py --list                        # grid + claim status
    scripts/sweep.py --preset smoke --hosts 2 --host-id 0 --store /tmp/s0
    scripts/sweep.py --tables t1 t2 --nafs sigmoid tanh --store /tmp/full
    scripts/sweep.py --tables t3 t5 t7 --backend jax --speculate 3
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
from pathlib import Path

from repro.compiler import (TableStore, merge_shards, paper_grid, run_live,
                            run_shard)
from repro.compiler.compile import SPECULATE_ENV
from repro.compiler.sweep import shard_jobs
from repro.core.searchspace import (BACKEND_ENV, SEARCH_BACKENDS,
                                    jax_backend_available)


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    p.add_argument("--preset", choices=("paper", "smoke"), default="paper")
    p.add_argument("--tables", nargs="*", default=None, metavar="tN",
                   help="restrict to table templates (t1..t7)")
    p.add_argument("--nafs", nargs="*", default=None,
                   help="restrict the NAF zoo")
    p.add_argument("--limit", type=int, default=None,
                   help="truncate the grid (debugging)")
    p.add_argument("--mode", choices=("sharded", "live"), default="sharded",
                   help="sharded: key-hash partition, own store dir per "
                   "host, merge afterwards; live: work-stealing over one "
                   "shared store dir, no merge")
    p.add_argument("--hosts", type=int, default=1)
    p.add_argument("--host-id", type=int, default=0,
                   help="shard selector (sharded) / worker label (live)")
    p.add_argument("--poll", type=float, default=0.5, metavar="SEC",
                   help="live mode: drain-pass poll interval")
    p.add_argument("--max-wait", type=float, default=600.0, metavar="SEC",
                   help="live mode: give up on foreign live claims after "
                   "SEC of waiting (deferred keys, exit 3)")
    p.add_argument("--no-drain", action="store_true",
                   help="live mode: defer foreign-claimed keys immediately "
                   "instead of waiting them out")
    p.add_argument("--store", type=Path, default=None,
                   help="store directory (default: REPRO_TABLE_CACHE)")
    p.add_argument("--backend", choices=sorted(SEARCH_BACKENDS),
                   default=None,
                   help="search backend for THIS host's compiles (numpy "
                   "golden / jitted jax; default $REPRO_SEARCH_BACKEND, "
                   "then numpy).  Execution-only: artifacts and store keys "
                   "are bit-identical across backends, so mixed-backend "
                   "fleets share one store")
    p.add_argument("--speculate", type=int, default=None, metavar="DEPTH",
                   help="TBW speculative probe batching depth for this "
                   "host (default $REPRO_TBW_SPECULATE, then 0 = off); "
                   "execution-only, like --backend")
    p.add_argument("--processes", type=int, default=None,
                   help="compile_batch pool size (1 = serial)")
    p.add_argument("--claim-ttl", type=float, default=None, metavar="SEC",
                   help="take over claims staler than SEC (default: defer)")
    p.add_argument("--owner", default=None,
                   help="claim owner tag (default host:pid)")
    p.add_argument("--retune", action="store_true",
                   help="run the per-device autotuner (smoke shape) "
                   "against --store before sweeping; the persisted winner "
                   "then drives this and every later sweep on this device")
    p.add_argument("--merge-from", nargs="*", type=Path, default=None,
                   metavar="DIR", help="merge shard dirs into --store "
                   "instead of compiling")
    p.add_argument("--list", action="store_true",
                   help="print this host's shard of the grid and exit")
    p.add_argument("--json", action="store_true", dest="as_json",
                   help="machine-readable report on stdout")
    return p


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    store = TableStore(args.store) if args.store else TableStore()

    if args.merge_from is not None:     # merge needs no grid enumeration
        stats = merge_shards(store, args.merge_from)
        out = {"mode": "merge", "store": str(store.root), "stats": stats}
        print(json.dumps(out) if args.as_json else
              f"[sweep] merged {len(args.merge_from)} shard dir(s) into "
              f"{store.root}: {stats}")
        return 0

    if args.retune:
        from repro.tune import autotune
        if not store.persist:
            print("[sweep] --retune on a memory-only store: measuring "
                  "without persisting", file=sys.stderr)
        autotune(store.root if store.persist else None, smoke=True)

    jobs = paper_grid(args.preset, nafs=args.nafs, tables=args.tables)
    if args.limit is not None:
        jobs = jobs[:args.limit]
    # execution-knob precedence: CLI flag > env var > per-device tuned
    # config > built-in defaults (docs/OPERATIONS.md "The autotuner").
    # The tuned config also sets process-level floors / block shape.
    tuned = None
    if store.persist:
        try:
            from repro.tune import activate, resolve_tuned
            tuned = resolve_tuned(store.root)
            if tuned is not None:
                activate(tuned)
        except Exception:
            tuned = None
    stamp_backend = args.backend
    if stamp_backend is None and not os.environ.get(BACKEND_ENV) and tuned:
        stamp_backend = tuned.search_backend
    stamp_spec = args.speculate
    if stamp_spec is None and not os.environ.get(SPECULATE_ENV) and tuned:
        stamp_spec = tuned.speculate
    # the flag, $REPRO_SEARCH_BACKEND and the tuned config are documented
    # as equivalent: degrade ANY of them to numpy with a notice when jax
    # x64 is missing, rather than erroring on every key of a live sweep
    effective_backend = stamp_backend or os.environ.get(BACKEND_ENV)
    if effective_backend == "jax":
        ok, why = jax_backend_available()
        if not ok:
            print(f"[sweep] jax search backend unavailable on this host "
                  f"({why}); falling back to numpy", file=sys.stderr)
            stamp_backend = "numpy"
    if stamp_backend is not None or stamp_spec is not None:
        # execution knobs only — job.key() ignores them, so the shard
        # partition and the store rendezvous are unchanged
        jobs = [dataclasses.replace(j, search_backend=stamp_backend,
                                    speculate=stamp_spec) for j in jobs]
    if args.list:
        # live mode has no partition: list the whole grid
        mine = (shard_jobs(jobs, args.hosts, args.host_id)
                if args.mode == "sharded"
                else [(j.key(), j.resolved()) for j in
                      dict((j.key(), j) for j in jobs).values()])
        rows = []
        for key, job in mine:
            # claim status makes a wedged sweep visible without reading
            # lease files by hand: free / claimed-by-<owner> / stale(...)
            state = ("stored" if store.contains(job) else
                     store.claim_status(key, ttl_s=args.claim_ttl))
            rows.append({"key": key, "naf": job.naf,
                         "scheme": job.scheme.tag,
                         "w_in": job.cfg.w_in, "w_out": job.cfg.w_out,
                         "state": state})
        if args.as_json:
            print(json.dumps({"mode": args.mode, "store": str(store.root),
                              "tuned": (dataclasses.asdict(tuned)
                                        if tuned else None),
                              "jobs": rows}))
        else:
            for r in rows:
                print(f"{r['key']}  {r['naf']:<12} {r['scheme']:<14} "
                      f"w{r['w_in']}->w{r['w_out']}  {r['state']}")
            scope = (f"shard {args.host_id}/{args.hosts}"
                     if args.mode == "sharded" else "live grid")
            print(f"[sweep] {scope}: {len(mine)} of {len(jobs)} unique "
                  f"jobs on {store.root}")
            print(f"[sweep] tuned config: "
                  f"{tuned.summary() if tuned else 'none for this device'}")
        return 0

    if args.mode == "live":
        report = run_live(jobs, store=store, workers=args.hosts,
                          worker_id=args.host_id, processes=args.processes,
                          claim_ttl_s=args.claim_ttl, owner=args.owner,
                          drain=not args.no_drain, poll_s=args.poll,
                          max_wait_s=args.max_wait)
        if args.as_json:
            print(json.dumps(dataclass_dict(report)))
        else:
            print(f"[sweep] live worker {report.host_id} on {store.root}: "
                  f"{len(report.compiled)} compiled, "
                  f"{len(report.loaded)} found stored, "
                  f"{len(report.taken_over)} stale claims taken over, "
                  f"{len(report.deferred)} deferred, "
                  f"{report.passes} passes "
                  f"({report.waited_s:.1f}s parked) "
                  f"in {report.wall_s:.1f}s -> {report.manifest_name}")
        return 0 if not report.deferred else 3

    report = run_shard(jobs, hosts=args.hosts, host_id=args.host_id,
                       store=store, processes=args.processes,
                       claim_ttl_s=args.claim_ttl, owner=args.owner)
    if args.as_json:
        print(json.dumps(dataclass_dict(report)))
    else:
        print(f"[sweep] shard {report.host_id}/{report.hosts} on "
              f"{store.root}: {len(report.compiled)} compiled, "
              f"{len(report.loaded)} resumed from store, "
              f"{len(report.deferred)} deferred (live claims), "
              f"{len(report.taken_over)} stale claims taken over "
              f"in {report.wall_s:.1f}s -> {report.manifest_name}")
    # deferred keys mean the sweep is not complete from this host's view
    return 0 if not report.deferred else 3


def dataclass_dict(report):
    import dataclasses
    return dataclasses.asdict(report)


if __name__ == "__main__":
    sys.exit(main())
