#!/usr/bin/env python
"""Multi-host design-space sweep CLI over the TableStore rendezvous.

Enumerates the paper's Tables I-VII x NAF-zoo grid as ``CompileJob``s and
runs *this host's* shard of it.  Sharding is deterministic store-key
hashing, so N hosts each running

    python scripts/sweep.py --hosts N --host-id i --store /shard/i

cover the grid exactly once with no coordinator.  The run is resumable
(store lookup before compile; re-run after a kill and only missing keys
compile) and lease-coordinated (claim files; ``--claim-ttl`` lets a
survivor take over a dead host's stale claims on a shared store).  Each
run writes a ``host<i>.manifest`` that ``--merge-from`` reconciles:

    python scripts/sweep.py --store /merged --merge-from /shard/0 /shard/1

merges shard directories into a store bit-identical to a single-host
serial compile of the same grid.

Examples:
    scripts/sweep.py --list                        # show the grid
    scripts/sweep.py --preset smoke --hosts 2 --host-id 0 --store /tmp/s0
    scripts/sweep.py --tables t1 t2 --nafs sigmoid tanh --store /tmp/full
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.compiler import TableStore, merge_shards, paper_grid, run_shard
from repro.compiler.sweep import shard_jobs


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    p.add_argument("--preset", choices=("paper", "smoke"), default="paper")
    p.add_argument("--tables", nargs="*", default=None, metavar="tN",
                   help="restrict to table templates (t1..t7)")
    p.add_argument("--nafs", nargs="*", default=None,
                   help="restrict the NAF zoo")
    p.add_argument("--limit", type=int, default=None,
                   help="truncate the grid (debugging)")
    p.add_argument("--hosts", type=int, default=1)
    p.add_argument("--host-id", type=int, default=0)
    p.add_argument("--store", type=Path, default=None,
                   help="store directory (default: REPRO_TABLE_CACHE)")
    p.add_argument("--processes", type=int, default=None,
                   help="compile_batch pool size (1 = serial)")
    p.add_argument("--claim-ttl", type=float, default=None, metavar="SEC",
                   help="take over claims staler than SEC (default: defer)")
    p.add_argument("--owner", default=None,
                   help="claim owner tag (default host:pid)")
    p.add_argument("--merge-from", nargs="*", type=Path, default=None,
                   metavar="DIR", help="merge shard dirs into --store "
                   "instead of compiling")
    p.add_argument("--list", action="store_true",
                   help="print this host's shard of the grid and exit")
    p.add_argument("--json", action="store_true", dest="as_json",
                   help="machine-readable report on stdout")
    return p


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    store = TableStore(args.store) if args.store else TableStore()

    if args.merge_from is not None:     # merge needs no grid enumeration
        stats = merge_shards(store, args.merge_from)
        out = {"mode": "merge", "store": str(store.root), "stats": stats}
        print(json.dumps(out) if args.as_json else
              f"[sweep] merged {len(args.merge_from)} shard dir(s) into "
              f"{store.root}: {stats}")
        return 0

    jobs = paper_grid(args.preset, nafs=args.nafs, tables=args.tables)
    if args.limit is not None:
        jobs = jobs[:args.limit]
    if args.list:
        mine = shard_jobs(jobs, args.hosts, args.host_id)
        for key, job in mine:
            print(f"{key}  {job.naf:<12} {job.scheme.tag:<14} "
                  f"w{job.cfg.w_in}->w{job.cfg.w_out}")
        print(f"[sweep] shard {args.host_id}/{args.hosts}: {len(mine)} of "
              f"{len(jobs)} unique jobs")
        return 0

    report = run_shard(jobs, hosts=args.hosts, host_id=args.host_id,
                       store=store, processes=args.processes,
                       claim_ttl_s=args.claim_ttl, owner=args.owner)
    if args.as_json:
        print(json.dumps(dataclass_dict(report)))
    else:
        print(f"[sweep] shard {report.host_id}/{report.hosts} on "
              f"{store.root}: {len(report.compiled)} compiled, "
              f"{len(report.loaded)} resumed from store, "
              f"{len(report.deferred)} deferred (live claims), "
              f"{len(report.taken_over)} stale claims taken over "
              f"in {report.wall_s:.1f}s -> {report.manifest_name}")
    # deferred keys mean the sweep is not complete from this host's view
    return 0 if not report.deferred else 3


def dataclass_dict(report):
    import dataclasses
    return dataclasses.asdict(report)


if __name__ == "__main__":
    sys.exit(main())
